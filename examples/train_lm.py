"""End-to-end training driver: train a ~100M-param llama-family config with
the full production stack (AdamW, deterministic pipeline, ENEC-compressed
checkpoints, straggler watchdog, resume).

CPU-sized default (--preset small trains a ~10M model for 200 steps in
minutes); --preset 100m is the full deliverable-(b) configuration — same
code, bigger dims (use on real accelerators).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.models import build_model
from repro.optim import adamw
from repro.runtime.train_loop import TrainLoopConfig, run

PRESETS = {
    # ~10M params: CPU-friendly smoke of the same architecture family
    "small": dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
                  head_dim=32, d_ff=1024, vocab_size=8192, seq=128, batch=8),
    # ~100M params: deliverable-(b) scale (run on accelerators)
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 head_dim=64, d_ff=3072, vocab_size=32768, seq=1024,
                 batch=64),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    ps = PRESETS[args.preset]
    cfg = dataclasses.replace(
        get_config("llama3_2_1b"), n_layers=ps["n_layers"],
        d_model=ps["d_model"], n_heads=ps["n_heads"],
        n_kv_heads=ps["n_kv_heads"], head_dim=ps["head_dim"],
        d_ff=ps["d_ff"], vocab_size=ps["vocab_size"], tie_embeddings=True,
        scan_layers=True, remat=False)
    model = build_model(cfg)
    from repro.models.registry import param_count
    print(f"[train_lm] {args.preset}: {param_count(cfg)/1e6:.1f}M params, "
          f"{args.steps} steps, batch {ps['batch']} x seq {ps['seq']}")

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=ps["seq"],
                          global_batch=ps["batch"], seed=0)
    opt_cfg = adamw.AdamWConfig(
        lr=args.lr, schedule=adamw.warmup_cosine(20, args.steps))
    ckpt = CheckpointManager(Path(args.ckpt_dir), keep_last=2)
    out = run(model, opt_cfg, data_cfg,
              TrainLoopConfig(total_steps=args.steps, ckpt_every=50,
                              log_every=10),
              ckpt=ckpt,
              on_metrics=lambda row: print(f"  step {row['step']:>5d} "
                                           f"loss {row['loss']:.4f} "
                                           f"({row['dt_s']*1e3:.0f} ms)"))
    first, last = out["history"][0], out["history"][-1]
    print(f"[train_lm] loss {first['loss']:.4f} -> {last['loss']:.4f} in "
          f"{out['wall_s']:.1f}s; checkpoints (ENEC-compressed) in "
          f"{args.ckpt_dir}")


if __name__ == "__main__":
    main()
